"""Correctness of the §Perf optimisation paths (EXPERIMENTS.md): every
variant must be semantically identical to the baseline it replaces."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.kernels import ref
from repro.models import attention as A
from repro.models import model as M
from repro.models import moe as moe_mod


def test_chunked_local_attention_matches_masked_full():
    # softcap 50 exercised (retargeted after the gemma2-27b config prune)
    cfg = dataclasses.replace(get_arch("gemma-2b").reduced(), logit_softcap=50.0)
    key = jax.random.PRNGKey(0)
    B, S, H, Kv, D, w = 2, 256, 4, 2, 32, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Kv, D))
    v = jax.random.normal(ks[2], (B, S, Kv, D))
    got = A._chunked_local_attention(cfg, q, k, v, w)
    want = ref.attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=True, window=w, softcap=cfg.logit_softcap,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


def test_moe_grouped_dispatch_matches_global():
    cfg = get_arch("grok-1-314b").reduced()  # MoE survivor of the config prune
    key = jax.random.PRNGKey(1)
    p, _ = moe_mod.init_moe(cfg, key)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 16, cfg.d_model))
    try:
        moe_mod.set_dispatch_groups(1)
        a, aux_a = moe_mod.apply_moe(cfg, p, x)
        moe_mod.set_dispatch_groups(2)
        b, aux_b = moe_mod.apply_moe(cfg, p, x)
    finally:
        moe_mod.set_dispatch_groups(1)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    assert abs(float(aux_a) - float(aux_b)) < 1e-5


def test_grad_accumulation_matches_single_step():
    cfg = get_arch("gemma-2b").reduced()
    key = jax.random.PRNGKey(3)
    state = M.init_train_state(cfg, key)
    batch = {"tokens": jax.random.randint(key, (4, 33), 0, cfg.vocab_size)}
    s1, m1 = jax.jit(lambda s, b: M.train_step(cfg, s, b, accum=1))(state, batch)
    s2, m2 = jax.jit(lambda s, b: M.train_step(cfg, s, b, accum=2))(state, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-4
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        s1.params, s2.params,
    )
    assert max(jax.tree.leaves(diffs)) < 5e-3  # same update up to accum numerics


def test_ce_onehot_loss_matches_takealong():
    """The sharded-safe one-hot CE must equal the gather formulation."""
    cfg = get_arch("gemma-2b").reduced()
    key = jax.random.PRNGKey(4)
    params = M.init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 65), 0, cfg.vocab_size)}
    loss = float(M.loss_fn(cfg, params, batch))
    # manual gather-based CE for comparison
    from repro.models.layers import unembed
    from repro.models.transformer import forward

    hidden, aux, _ = forward(cfg, params, batch["tokens"][:, :-1])
    logits = unembed(cfg, params["embed"], hidden)
    t = batch["tokens"][:, 1:]
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
    want = float(jnp.mean(lse - ll) + M.MOE_AUX_WEIGHT * aux)
    assert abs(loss - want) < 1e-4, (loss, want)
