"""Elastic federation runtime (fl/elastic.py): lockstep equivalence,
participation-machinery invariants, and in-process chaos.

The multi-process chaos harness lives in tests/test_elastic_chaos.py;
the hypothesis-driven generalisations of the invariants here live in
tests/test_elastic_property.py (skipped without the dev extra — this
module keeps deterministic seeded versions in tier 1).
"""
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import scoring
from repro.core.plan import adaboost_plan, bagging_plan
from repro.data import get_dataset
from repro.fl.elastic import (
    ElasticFederation, FaultPlan, ParticipationPolicy, _ArrivalBoard,
    staleness_discount,
)
from repro.fl.federation import Federation
from repro.fl.partition import iid_partition
from repro.learners import LearnerSpec

ALGOS = ["adaboost_f", "distboost_f", "preweak_f", "bagging"]
C, T = 4, 3


@pytest.fixture(scope="module")
def setup():
    dspec, (Xtr, ytr, Xte, yte) = get_dataset("vehicle", jax.random.PRNGKey(0))
    Xs, ys, masks = iid_partition(Xtr, ytr, C, jax.random.PRNGKey(1))
    lspec = LearnerSpec("decision_tree", dspec.n_features, dspec.n_classes,
                        {"depth": 3, "n_bins": 8})
    return Xs, ys, masks, Xte, yte, lspec


def _make_plan(alg, rounds=T):
    return (bagging_plan(rounds=rounds) if alg == "bagging"
            else adaboost_plan(rounds=rounds, algorithm=alg))


def _run(setup, alg, rounds=T, **run_kw):
    Xs, ys, masks, Xte, yte, lspec = setup
    fed = Federation(_make_plan(alg, rounds), Xs, ys, masks, Xte, yte,
                     lspec, jax.random.PRNGKey(2))
    hist = fed.run(eval_every=1, **run_kw)
    return fed, hist


# -- the tentpole contract: all-ones participation == lockstep, to the bit


@pytest.mark.parametrize("alg", ALGOS)
def test_elastic_noop_policy_equals_lockstep_bitforbit(setup, alg):
    """With no faults and deadline=None the elastic runtime reproduces
    lockstep ``Federation.run`` exactly — history, weights, ensemble
    leaves — for every algorithm (the test_distributed.py contract
    applied to the elastic loop)."""
    lock, hist_lock = _run(setup, alg)
    elas, hist_elas = _run(setup, alg, policy=ParticipationPolicy(deadline_s=None))
    assert len(hist_lock) == len(hist_elas)
    for a, b in zip(hist_lock, hist_elas):
        for k in ("f1", "epsilon", "alpha", "chosen"):
            assert a[k] == b[k], (alg, k)
    s1, s2 = lock._fused_state, elas._fused_state
    np.testing.assert_array_equal(np.asarray(s1.weights), np.asarray(s2.weights))
    np.testing.assert_array_equal(np.asarray(s1.ensemble.alpha),
                                  np.asarray(s2.ensemble.alpha))
    assert int(s1.ensemble.count) == int(s2.ensemble.count)
    for l1, l2 in zip(jax.tree.leaves(s1.ensemble.params),
                      jax.tree.leaves(s2.ensemble.params)):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


# -- participation machinery invariants (deterministic seeds; the
# hypothesis generalisation lives in test_elastic_property.py)


def test_masked_helpers_all_ones_identity():
    rng = np.random.default_rng(0)
    errs = jnp.asarray(rng.random((5, 7)), jnp.float32)
    w = jnp.asarray(rng.random((5, 11)), jnp.float32)
    w = w / jnp.sum(w)
    part = jnp.ones(5, jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(scoring.masked_error_sum(errs, part)),
        np.asarray(jnp.sum(errs, axis=0)),
    )
    eps = jnp.sum(errs, axis=0)
    hyp_part = jnp.ones(7, jnp.float32)
    assert int(scoring.masked_argmin(eps, hyp_part)) == int(jnp.argmin(eps))
    assert float(scoring.participation_denom(w, part)) == 1.0
    mis = jnp.asarray(rng.integers(0, 2, (5, 11)), jnp.float32)
    mask = jnp.ones((5, 11), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(scoring.masked_update_weights(w, mis, mask, part, 0.7)),
        np.asarray(scoring.update_weights(w, mis, mask, 0.7)),
    )


def test_masked_aggregation_permutation_invariant_in_dropped_set():
    """What the dropped collaborators' rows CONTAIN cannot matter: with
    the same responder set, permuting (or scrambling) absent rows leaves
    the chosen error, the denominator, and every responder's updated
    weight row unchanged."""
    rng = np.random.default_rng(1)
    Cn, H, n = 6, 8, 13
    errs = jnp.asarray(rng.random((Cn, H)), jnp.float32)
    w = jnp.asarray(rng.random((Cn, n)), jnp.float32)
    w = w / jnp.sum(w)
    mis = jnp.asarray(rng.integers(0, 2, (Cn, n)), jnp.float32)
    mask = jnp.ones((Cn, n), jnp.float32)
    part = jnp.asarray([1, 0, 1, 0, 0, 1], jnp.float32)
    dropped = [1, 3, 4]

    scrambled_errs = errs.at[jnp.asarray(dropped)].set(
        jnp.asarray(rng.random((3, H)), jnp.float32) * 100.0
    )
    eps_a = scoring.masked_error_sum(errs, part)
    eps_b = scoring.masked_error_sum(scrambled_errs, part)
    np.testing.assert_array_equal(np.asarray(eps_a), np.asarray(eps_b))

    # absent rows' mis cannot move responders' updated weights
    scrambled_mis = mis.at[jnp.asarray(dropped)].set(1.0 - mis[jnp.asarray(dropped)])
    w_a = scoring.masked_update_weights(w, mis, mask, part, 0.9)
    w_b = scoring.masked_update_weights(w, scrambled_mis, mask, part, 0.9)
    resp = np.asarray(part) > 0
    np.testing.assert_array_equal(np.asarray(w_a)[resp], np.asarray(w_b)[resp])

    # and a permutation among the dropped rows leaves the denominator fixed
    perm = jnp.asarray([0, 3, 2, 4, 1, 5])
    assert float(scoring.participation_denom(w, part)) == float(
        scoring.participation_denom(w[perm], part[perm])
    )


def test_staleness_discount_monotone_in_lateness():
    for gamma in (0.25, 0.5, 0.9, 1.0):
        ds = [staleness_discount(gamma, k) for k in range(6)]
        assert ds[0] == 1.0
        assert all(a >= b for a, b in zip(ds, ds[1:]))
    with pytest.raises(ValueError):
        staleness_discount(0.0, 1)
    with pytest.raises(ValueError):
        staleness_discount(0.5, -1)


# -- fault plans are deterministic and seed-driven


def test_fault_plan_schedule_deterministic():
    fp = FaultPlan(seed=42, delay_p=0.3, delay_range_s=(0.1, 0.5),
                   drop_p=0.2, kills=((1, 3),), flaky=((2, 1, 4),))
    a, b = fp.schedule(6, 4), fp.schedule(6, 4)
    np.testing.assert_array_equal(a.delay, b.delay)
    np.testing.assert_array_equal(a.drop, b.drop)
    assert not a.alive[3:, 1].any() and a.alive[:3, 1].all()
    assert a.offline[1:4, 2].all() and not a.offline[4:, 2].any()
    assert (a.delay[a.delay > 0] >= 0.1).all()


# -- in-process chaos: kills + drops, then delay-only late merges


def test_virtual_chaos_kills_and_drops(setup):
    Xs, ys, masks, Xte, yte, lspec = setup
    rounds = 6
    fed = Federation(_make_plan("adaboost_f", rounds), Xs, ys, masks,
                     Xte, yte, lspec, jax.random.PRNGKey(2))
    hist = fed.run(
        eval_every=1,
        policy=ParticipationPolicy(deadline_s=1.0),
        faults=FaultPlan(seed=7, drop_p=0.2, kills=((2, 3),)),
    )
    e = fed.elastic
    assert len(hist) == rounds  # the federation finishes every round
    assert e.dropouts["dead"] == 1
    assert all(r <= C - 1 for r in e.responders_log[3:])  # 2 is gone for good
    assert hist[-1]["f1"] > 0.6
    assert all(row["responders"] >= 1 for row in hist)


def test_virtual_delay_only_late_merges_land_discounted(setup):
    Xs, ys, masks, Xte, yte, lspec = setup
    rounds = 6
    fed = Federation(_make_plan("adaboost_f", rounds), Xs, ys, masks,
                     Xte, yte, lspec, jax.random.PRNGKey(2))
    fed.run(
        eval_every=1,
        policy=ParticipationPolicy(deadline_s=0.5, staleness_gamma=0.5,
                                   max_staleness=2),
        faults=FaultPlan(seed=3, delay_p=0.4, delay_range_s=(0.6, 1.4)),
    )
    e = fed.elastic
    assert e.late_log, "expected stragglers to merge late"
    for row in e.late_log:
        assert row["alpha"] <= row["base_alpha"]
        assert row["discount"] == staleness_discount(0.5, row["lateness"])
        # monotone: two rounds late is discounted at least as hard as one
    by_lateness = sorted(e.late_log, key=lambda r: r["lateness"])
    for a, b in zip(by_lateness, by_lateness[1:]):
        assert a["discount"] >= b["discount"]
    skipped = sum(1 for r in e.responders_log if r == 0)
    assert int(np.asarray(e.state.ensemble.count)) == \
        rounds - skipped + len(e.late_log)


def test_membership_churn_joins_and_leaves(setup):
    """A collaborator joining at round 2 and another leaving at round 3:
    the responder counts must track the membership windows."""
    Xs, ys, masks, Xte, yte, lspec = setup
    rounds = 5
    fed = Federation(_make_plan("adaboost_f", rounds), Xs, ys, masks,
                     Xte, yte, lspec, jax.random.PRNGKey(2))
    hist = fed.run(
        eval_every=1,
        policy=ParticipationPolicy(deadline_s=1.0, joins=((1, 2),),
                                   leaves=((3, 3),)),
        faults=FaultPlan(),
    )
    e = fed.elastic
    assert e.responders_log == [3, 3, 4, 3, 3]
    assert len(hist) == rounds


def test_realtime_board_respects_deadline_and_floor():
    board = _ArrivalBoard()
    board.post(0, 0)
    t0 = time.monotonic()
    resp, late, wait, hit = board.close_round(0, {0, 1}, 0.2, 1)
    assert resp == {0} and hit and wait >= 0.2
    assert time.monotonic() - t0 < 2.0
    # the floor stretches the deadline until an arrival lands
    import threading
    threading.Timer(0.3, board.post, (1, 1)).start()
    resp, late, wait, hit = board.close_round(1, {1}, 0.05, 1)
    assert resp == {1} and wait >= 0.25
    # a straggler posting for an old round surfaces as a late post
    board.post(1, 0)
    resp, late, _, _ = board.close_round(2, set(), None, 1)
    assert late == [(1, 0)]


def test_realtime_mode_smoke(setup):
    Xs, ys, masks, Xte, yte, lspec = setup
    fed = Federation(_make_plan("adaboost_f", 3), Xs, ys, masks,
                     Xte, yte, lspec, jax.random.PRNGKey(2))
    hist = fed.run(
        eval_every=1,
        policy=ParticipationPolicy(deadline_s=0.15, realtime=True),
        faults=FaultPlan(seed=5, delay_p=0.5, delay_range_s=(0.3, 0.5)),
    )
    e = fed.elastic
    assert len(hist) == 3
    assert all(r >= 1 for r in e.responders_log)  # min_responders floor


def test_elastic_rejects_hetero_and_interpreted(setup):
    Xs, ys, masks, Xte, yte, lspec = setup
    from repro.core.hetero import HeterogeneousSpec

    hspec = HeterogeneousSpec.cycle(
        ["decision_tree", "gaussian_nb"], C, lspec.n_features, lspec.n_classes,
        hparams={"decision_tree": {"depth": 3, "n_bins": 8}},
    )
    fed = Federation(_make_plan("adaboost_f"), Xs, ys, masks, Xte, yte,
                     hspec, jax.random.PRNGKey(2))
    with pytest.raises(NotImplementedError):
        fed.run(policy=ParticipationPolicy())
    with pytest.raises(ValueError):
        ParticipationPolicy(deadline_s=-1.0).validate()
    with pytest.raises(ValueError):
        ParticipationPolicy(staleness_gamma=1.5).validate()


# -- launcher: _join_all can no longer hang on a wedged process


def _sleeper(seconds: float) -> subprocess.Popen:
    return subprocess.Popen(
        [sys.executable, "-c", f"import time; time.sleep({seconds})"],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
    )


def test_join_all_kills_hung_orphans(capsys):
    from repro.launch.fl_spawn import _join_all

    procs = [_sleeper(0.2), _sleeper(60.0)]
    t0 = time.monotonic()
    rcs = _join_all(procs, [None, None], timeout=5.0, grace=0.5)
    assert time.monotonic() - t0 < 10.0
    assert rcs[0] == 0 and rcs[1] == 124
    assert procs[1].poll() is not None  # really killed, not leaked


def test_join_all_happy_path_streams_stdout():
    from repro.launch.fl_spawn import _join_all

    procs = [
        subprocess.Popen([sys.executable, "-c", "print('final F1 0.9000')"],
                         stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
                         text=True),
        _sleeper(0.1),
    ]
    out: list = []
    import io
    rcs = _join_all(procs, [None, None], timeout=30.0, out_lines=out,
                    stream=io.StringIO())
    assert rcs == [0, 0]
    assert "final F1 0.9000" in "".join(out)
