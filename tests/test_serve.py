"""Serving subsystem: artifact save→load→predict round-trip for every
registered learner, engine-vs-strong_predict bit-for-bit parity on
ragged final batches, vote-cache correctness across ensemble growth,
vote_argmax kernel parity, and fit-cache round equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import boosting
from repro.kernels import ref
from repro.kernels.vote_argmax import vote_argmax
from repro.learners import LearnerSpec, available_learners, get_learner
from repro.serve import ServeEngine, ShardVoteCache, load_artifact, save_artifact

HPARAMS = {
    "decision_tree": {"depth": 3, "n_bins": 8},
    "extra_tree": {"depth": 3, "n_bins": 8, "max_candidates": 16},
    "ridge": {"l2": 1.0},
    "mlp": {"hidden": 16, "steps": 30, "lr": 0.05},
    "gaussian_nb": {},
    "nearest_centroid": {},
}


def _blobs(key, n=240, d=6, K=3, sep=3.0):
    kc, kx, ky = jax.random.split(key, 3)
    centers = jax.random.normal(kc, (K, d)) * sep
    y = jax.random.randint(ky, (n,), 0, K)
    return centers[y] + jax.random.normal(kx, (n, d)), y


def _small_ensemble(name, key, T=3, committee_size=None):
    """A tiny trained ensemble for `name` (fits T members directly)."""
    X, y = _blobs(key)
    spec = LearnerSpec(name, X.shape[1], 3, HPARAMS[name])
    learner = get_learner(name)
    ens = boosting.init_ensemble(learner, spec, T, key, committee_size=committee_size)
    w = jnp.ones(y.shape, jnp.float32)
    for t in range(T):
        kt = jax.random.fold_in(key, t)
        p = learner.fit(spec, None, X, y, w * (0.5 + 0.5 * t), kt)
        if committee_size is not None:
            p = jax.tree.map(lambda x: jnp.broadcast_to(x, (committee_size,) + x.shape), p)
        ens = boosting.Ensemble(
            params=boosting._set_slot(ens.params, ens.count, p),
            alpha=ens.alpha.at[ens.count].set(0.3 + 0.2 * t),
            count=ens.count + 1,
        )
    return learner, spec, ens, X


# ---------------------------------------------------------------------------
# Artifact round-trip — every learner in the registry
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(HPARAMS))
def test_artifact_roundtrip_every_learner(name, tmp_path):
    assert name in available_learners()
    learner, spec, ens, X = _small_ensemble(name, jax.random.PRNGKey(0))
    path = save_artifact(tmp_path / f"{name}.mafl", spec, ens)
    art = load_artifact(path)
    assert art.spec == spec and not art.committee
    assert art.manifest["ensemble_count"] == 3
    want = boosting.strong_predict(learner, spec, ens, X)
    got = boosting.strong_predict(art.learner, art.spec, art.ensemble, X)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_artifact_roundtrip_committee(tmp_path):
    """DistBoost.F artifacts carry a committee per slot."""
    learner, spec, ens, X = _small_ensemble(
        "nearest_centroid", jax.random.PRNGKey(1), committee_size=2
    )
    path = save_artifact(tmp_path / "c.mafl", spec, ens, committee_size=2)
    art = load_artifact(path)
    assert art.committee and art.committee_size == 2
    want = boosting.strong_predict(learner, spec, ens, X, committee=True)
    got = boosting.strong_predict(art.learner, art.spec, art.ensemble, X, committee=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_artifact_rejects_shadowing_extra_keys(tmp_path):
    _, spec, ens, _ = _small_ensemble("ridge", jax.random.PRNGKey(2))
    with pytest.raises(ValueError, match="shadow"):
        save_artifact(tmp_path / "x.mafl", spec, ens, extra={"payload_crc32": 0})


def test_artifact_rejects_corruption(tmp_path):
    _, spec, ens, _ = _small_ensemble("ridge", jax.random.PRNGKey(2))
    path = save_artifact(tmp_path / "r.mafl", spec, ens)
    blob = bytearray(path.read_bytes())
    blob[-3] ^= 0xFF  # flip a payload bit
    path.write_bytes(bytes(blob))
    with pytest.raises(ValueError, match="checksum"):
        load_artifact(path)


def test_artifact_truncation_raises_valueerror_at_every_byte(tmp_path):
    """A file cut ANYWHERE — inside the magic, inside the u32 manifest
    length, mid-manifest, mid-payload — must raise the documented
    ValueError, never a raw struct.error / JSONDecodeError."""
    _, spec, ens, _ = _small_ensemble("ridge", jax.random.PRNGKey(2))
    data = save_artifact(tmp_path / "r.mafl", spec, ens).read_bytes()
    path = tmp_path / "trunc.mafl"
    for k in range(len(data)):  # every proper prefix, empty file included
        path.write_bytes(data[:k])
        with pytest.raises(ValueError):
            load_artifact(path)


def test_artifact_corrupt_manifest_raises_valueerror(tmp_path):
    import json
    import struct

    from repro.serve.artifact import MAGIC

    _, spec, ens, _ = _small_ensemble("ridge", jax.random.PRNGKey(2))
    data = save_artifact(tmp_path / "r.mafl", spec, ens).read_bytes()
    hdr = len(MAGIC) + 4
    (mlen,) = struct.unpack("<I", data[len(MAGIC):hdr])
    payload = data[hdr + mlen:]
    path = tmp_path / "bad.mafl"

    def rebuild(manifest_blob: bytes) -> None:
        path.write_bytes(MAGIC + struct.pack("<I", len(manifest_blob))
                         + manifest_blob + payload)

    rebuild(b"\xff" * mlen)  # not JSON at all
    with pytest.raises(ValueError, match="corrupt manifest"):
        load_artifact(path)
    rebuild(b"[1, 2, 3]")  # JSON, but not an object
    with pytest.raises(ValueError, match="not a JSON object"):
        load_artifact(path)
    rebuild(json.dumps({"format_version": 1}).encode())  # object, keys missing
    with pytest.raises(ValueError, match="missing required keys"):
        load_artifact(path)


# ---------------------------------------------------------------------------
# Engine — bit-for-bit vs strong_predict, ragged tail included
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["decision_tree", "gaussian_nb"])
@pytest.mark.parametrize("m,B", [(333, 128), (64, 64), (5, 256)])
def test_engine_bitforbit_vs_strong_predict(name, m, B):
    learner, spec, ens, _ = _small_ensemble(name, jax.random.PRNGKey(3))
    X, _ = _blobs(jax.random.PRNGKey(4), n=m)
    want = np.asarray(boosting.strong_predict(learner, spec, ens, X))
    engine = ServeEngine(learner, spec, ens, batch_size=B)
    np.testing.assert_array_equal(engine.predict(np.asarray(X)), want)
    # ragged tail was padded up to the static batch shape
    assert engine.stats.batches == -(-m // B)
    assert engine.stats.padded_rows == engine.stats.batches * B - m


def test_engine_serves_committee_artifacts(tmp_path):
    """A DistBoost.F artifact must serve with committee vote folding."""
    learner, spec, ens, X = _small_ensemble(
        "nearest_centroid", jax.random.PRNGKey(14), committee_size=2
    )
    art = load_artifact(
        save_artifact(tmp_path / "c.mafl", spec, ens, committee_size=2)
    )
    want = np.asarray(
        boosting.strong_predict(art.learner, art.spec, art.ensemble, X, committee=True)
    )
    engine = ServeEngine(
        art.learner, art.spec, art.ensemble, batch_size=64, committee=art.committee
    )
    np.testing.assert_array_equal(engine.predict(np.asarray(X)), want)
    cache = ShardVoteCache(
        art.learner, art.spec, art.ensemble, committee=art.committee
    )
    np.testing.assert_array_equal(cache.predict("s", X), want)


@pytest.mark.parametrize("name", sorted(HPARAMS))
def test_every_learner_serves_behind_one_api(name):
    """The predict-signature audit: every registry entry serves through
    the same engine code path, ragged tail included, bit for bit."""
    learner, spec, ens, _ = _small_ensemble(name, jax.random.PRNGKey(20))
    X, _ = _blobs(jax.random.PRNGKey(21), n=100)
    want = np.asarray(boosting.strong_predict(learner, spec, ens, X))
    got = ServeEngine(learner, spec, ens, batch_size=64).predict(np.asarray(X))
    np.testing.assert_array_equal(got, want)


def test_engine_scheduler_matches_sync_path():
    learner, spec, ens, _ = _small_ensemble("decision_tree", jax.random.PRNGKey(5))
    X, _ = _blobs(jax.random.PRNGKey(6), n=150)
    Xn = np.asarray(X)
    engine = ServeEngine(learner, spec, ens, batch_size=64)
    want = engine.predict(Xn)
    sched = ServeEngine(learner, spec, ens, batch_size=64)
    ids = []
    for i in range(0, 150, 7):  # ragged request stream
        ids.extend(sched.submit(Xn[i : i + 7]))
    assert len(sched.results) == 128  # two full batches ran eagerly
    sched.flush()
    np.testing.assert_array_equal(np.array([sched.take(i) for i in ids]), want)
    assert not sched.results  # take() pops: nothing pinned after reading
    assert len(sched.stats.request_latencies) == 150


def test_engine_compile_cache_is_warm_across_batches():
    learner, spec, ens, _ = _small_ensemble("ridge", jax.random.PRNGKey(7))
    X, _ = _blobs(jax.random.PRNGKey(8), n=500)
    engine = ServeEngine(learner, spec, ens, batch_size=128)
    engine.predict(np.asarray(X))
    assert engine.stats.batches == 4
    # one program per (learner, B) — possibly borrowed warm from the
    # process-wide compile cache if an earlier test already built it
    assert engine.stats.compiles + engine.stats.cache_hits == 1
    # a grown ensemble must NOT recompile (static slot shapes)
    engine.update_ensemble(ens._replace(count=ens.count - 1))
    engine.predict(np.asarray(X))
    assert engine.stats.compiles + engine.stats.cache_hits == 1


def test_update_ensemble_rejects_foreign_structure():
    """Same alpha capacity is NOT identity: an ensemble from a different
    learner (or a different spec of the same learner) must be rejected —
    swapping it under the warm compiled predict would serve garbage."""
    learner, spec, ens, X = _small_ensemble("decision_tree", jax.random.PRNGKey(15))
    engine = ServeEngine(learner, spec, ens, batch_size=64)
    engine.predict(np.asarray(X))

    # different learner, same capacity T=3 and same alpha shape
    _, _, foreign, _ = _small_ensemble("ridge", jax.random.PRNGKey(16))
    assert foreign.alpha.shape == ens.alpha.shape
    with pytest.raises(ValueError, match="structure"):
        engine.update_ensemble(foreign)

    # same learner, different hparams -> different leaf shapes
    shallow_spec = LearnerSpec("decision_tree", spec.n_features, 3,
                               {"depth": 2, "n_bins": 8})
    shallow = boosting.init_ensemble(learner, shallow_spec, 3, jax.random.PRNGKey(17))
    assert shallow.alpha.shape == ens.alpha.shape
    with pytest.raises(ValueError, match="structure"):
        engine.update_ensemble(shallow)

    # a genuinely matching ensemble still swaps in without recompiling
    programs = engine.stats.compiles + engine.stats.cache_hits
    engine.update_ensemble(ens._replace(alpha=ens.alpha * 2.0))
    engine.predict(np.asarray(X))
    assert engine.stats.compiles + engine.stats.cache_hits == programs


def test_update_ensemble_publishes_atomically():
    """Regression: a hot swap is ONE attribute store of the
    (ensemble, active-mask) pair, so a dispatching thread can never see
    a new ensemble with a stale mask (or vice versa)."""
    learner, spec, ens, X = _small_ensemble("decision_tree", jax.random.PRNGKey(18))
    engine = ServeEngine(learner, spec, ens, batch_size=64)
    engine.predict(np.asarray(X))

    stores = []
    cls = type(engine)

    class Spy(cls):
        def __setattr__(self, name, value):
            if name == "_live":
                stores.append(value)
            super().__setattr__(name, value)

    engine.__class__ = Spy
    swapped = ens._replace(alpha=ens.alpha * 2.0)
    engine.update_ensemble(swapped)
    engine.__class__ = cls
    # exactly one publication, carrying ensemble and mask together
    assert len(stores) == 1 and len(stores[0]) == 2
    assert stores[0][0] is swapped
    # readers resolve both views out of the published pair
    assert engine.ensemble is swapped
    assert engine._active == engine._compute_active(swapped)


# ---------------------------------------------------------------------------
# Shard-resident vote cache — correctness while the ensemble grows
# ---------------------------------------------------------------------------


def test_vote_cache_correct_when_ensemble_grows():
    key = jax.random.PRNGKey(9)
    X, y = _blobs(key, n=300)
    spec = LearnerSpec("decision_tree", X.shape[1], 3, HPARAMS["decision_tree"])
    learner = get_learner("decision_tree")
    Xs, ys = X[None], y[None]
    masks = jnp.ones(ys.shape, jnp.float32)
    state = boosting.init_boost_state(learner, spec, 6, masks, key, X=Xs)
    rfn = jax.jit(lambda s: boosting.adaboost_f_round(learner, spec, s, Xs, ys, masks))
    for _ in range(3):
        state, _ = rfn(state)

    Xq, _ = _blobs(jax.random.PRNGKey(10), n=111)
    cache = ShardVoteCache(learner, spec, state.ensemble)
    p1 = cache.predict("q", Xq)  # miss: full tally build
    want = np.asarray(boosting.strong_predict(learner, spec, state.ensemble, Xq))
    np.testing.assert_array_equal(p1, want)
    np.testing.assert_array_equal(cache.predict("q"), want)  # pure hit

    for _ in range(3):  # the federation keeps training between requests
        state, _ = rfn(state)
    cache.update_ensemble(state.ensemble)
    p2 = cache.predict("q")  # partial hit: folds ONLY the 3 new members
    want2 = np.asarray(boosting.strong_predict(learner, spec, state.ensemble, Xq))
    np.testing.assert_array_equal(p2, want2)
    assert cache.stats() == {
        "shards": 1, "hits": 1, "partial_hits": 1, "misses": 1,
        "members_folded": 6, "reregistrations": 0,
    }
    with pytest.raises(ValueError, match="shrank"):
        cache.update_ensemble(state.ensemble._replace(count=jnp.zeros((), jnp.int32)))
    # replacing already-tallied members (a retrain, not an append) must be
    # rejected — the resident tallies would silently serve the old model
    mutated = state.ensemble._replace(alpha=state.ensemble.alpha.at[0].mul(2.0))
    with pytest.raises(ValueError, match="append-only"):
        cache.update_ensemble(mutated)

    # key reuse with DIFFERENT rows must re-register, never serve the old
    # shard's tally for the new rows
    Xq2, _ = _blobs(jax.random.PRNGKey(22), n=111)
    p3 = cache.predict("q", Xq2)
    want3 = np.asarray(boosting.strong_predict(learner, spec, state.ensemble, Xq2))
    np.testing.assert_array_equal(p3, want3)
    assert cache.stats()["reregistrations"] == 1  # counted, not silent


def test_vote_cache_fingerprint_is_dtype_insensitive():
    """Repeat traffic held in float64 by the caller must stay a cache
    hit: the cache serves float32, so the fingerprint is taken over the
    f32-normalised rows — a f64 re-send of the same rows is the SAME
    shard, not a re-registration (which would rebuild the tally and turn
    every hit into a full-tally miss)."""
    learner, spec, ens, _ = _small_ensemble("decision_tree", jax.random.PRNGKey(30))
    Xq, _ = _blobs(jax.random.PRNGKey(31), n=90)
    X32 = np.asarray(Xq, np.float32)
    X64 = X32.astype(np.float64)

    cache = ShardVoteCache(learner, spec, ens)
    want = cache.predict("s", X32)  # miss: builds residency
    for _ in range(3):  # dtype-mismatched repeat traffic stays a pure hit
        np.testing.assert_array_equal(cache.predict("s", X64), want)
    st = cache.stats()
    assert st == {
        "shards": 1, "hits": 3, "partial_hits": 0, "misses": 1,
        "members_folded": 3, "reregistrations": 0,
    }
    # and the other direction: first contact in f64, repeats in f32
    cache2 = ShardVoteCache(learner, spec, ens)
    np.testing.assert_array_equal(cache2.predict("s", X64), want)
    np.testing.assert_array_equal(cache2.predict("s", X32), want)
    assert cache2.stats()["hits"] == 1 and cache2.stats()["reregistrations"] == 0


# ---------------------------------------------------------------------------
# vote_argmax kernel parity (interpret mode on CPU)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("T,n,K,block_t,block_n", [
    (13, 1000, 7, 8, 256),   # T % block_t != 0, n % block_n != 0
    (5, 31, 3, 32, 1024),    # everything smaller than one block
    (33, 2049, 10, 16, 512), # n one past a block boundary
])
def test_vote_argmax_kernel_parity(T, n, K, block_t, block_n):
    k1, k2 = jax.random.split(jax.random.PRNGKey(T * n), 2)
    preds = jax.random.randint(k1, (T, n), 0, K)
    # half-integer alphas: vote sums are exact in f32, so kernel block
    # order cannot flip the argmax and parity is exact
    alpha = jax.random.randint(k2, (T,), 1, 9).astype(jnp.float32) * 0.5
    alpha = alpha * (jnp.arange(T) < T - 2)  # unused tail slots vote 0
    got = vote_argmax(preds, alpha, n_classes=K, block_t=block_t,
                      block_n=block_n, interpret=True)
    want = ref.vote_argmax_ref(preds, alpha, K)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_engine_pallas_path_matches_ref_path():
    learner, spec, ens, _ = _small_ensemble("decision_tree", jax.random.PRNGKey(11))
    X, _ = _blobs(jax.random.PRNGKey(12), n=200)
    ref_pred = ServeEngine(learner, spec, ens, batch_size=64).predict(np.asarray(X))
    pal_pred = ServeEngine(
        learner, spec, ens, batch_size=64, use_pallas=True
    ).predict(np.asarray(X))
    np.testing.assert_array_equal(ref_pred, pal_pred)


# ---------------------------------------------------------------------------
# Fit cache (quantile bin edges) — cached rounds identical to uncached
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["decision_tree", "extra_tree"])
def test_fit_cache_rounds_bitforbit(name):
    key = jax.random.PRNGKey(13)
    X, y = _blobs(key, n=260)
    spec = LearnerSpec(name, X.shape[1], 3, HPARAMS[name])
    learner = get_learner(name)
    Xs, ys = jnp.stack([X[:130], X[130:]]), jnp.stack([y[:130], y[130:]])
    masks = jnp.ones(ys.shape, jnp.float32)
    s_plain = boosting.init_boost_state(learner, spec, 3, masks, key)
    s_cached = boosting.init_boost_state(learner, spec, 3, masks, key, X=Xs)
    assert s_plain.fit_cache is None and s_cached.fit_cache is not None
    for _ in range(3):
        s_plain, m_p = boosting.adaboost_f_round(learner, spec, s_plain, Xs, ys, masks)
        s_cached, m_c = boosting.adaboost_f_round(learner, spec, s_cached, Xs, ys, masks)
        assert int(m_p["chosen"]) == int(m_c["chosen"])
    np.testing.assert_array_equal(np.asarray(s_plain.weights), np.asarray(s_cached.weights))
    np.testing.assert_array_equal(
        np.asarray(s_plain.ensemble.alpha), np.asarray(s_cached.ensemble.alpha)
    )
